"""Perf hillclimbing driver (§Perf): re-lower a dry-run cell with config
overrides and report the three roofline terms + top collective contributors.

    PYTHONPATH=src python -m benchmarks.perf_iterations --arch X --shape Y \
        [--mesh single|multi] [--zero 1|3] [--micro-tokens 8192] \
        [--seq-shard-acts] [--cross-dtype bfloat16] \
        [--mode flat|hier|pipelined] [--n-channels 4] [--top 8]

Each invocation = one measurement of the hypothesis->change->measure loop;
results are appended to results/perf_log.jsonl.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import RunConfig
from repro.core.balance import uniform_plan
from repro.launch.mesh import (make_production_mesh, mesh_axis_sizes,
                               pod_size_of)
from repro.models import build
from repro.roofline import analysis as A
from repro.roofline.analysis import Roofline, analyze_hlo
from repro.launch.dryrun import (_serve_batch_sds, _train_batch_sds,
                                 model_flops_spec)
from repro.train.trainer import make_train_program


def top_collectives(hlo: str, n_devices: int, top: int = 8):
    comps = A._split_computations(hlo)
    parsed = {k: A._parse_ops(v) for k, v in comps.items()}
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            entry = re.match(r"ENTRY\s+%?([\w.\-]+)", line).group(1)
    mult_of, rows = {}, []

    def visit(comp, mult):
        if comp not in parsed or mult_of.get(comp, 0) >= mult:
            return
        mult_of[comp] = mult
        for op in parsed[comp].values():
            if op.kind == "while":
                m = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                b = re.search(r"body=%?([\w.\-]+)", op.attrs)
                trips = A._trip_count(comps.get(m.group(1), [])) if m else 1
                if b:
                    visit(b.group(1), mult * max(trips, 1))
            elif op.kind in ("fusion", "call", "custom-call"):
                for cm in re.finditer(r"(?:calls|to_apply)=\{?%?([\w.\-]+)",
                                      op.attrs):
                    visit(cm.group(1), mult)

    visit(entry, 1.0)
    for comp, mult in mult_of.items():
        duplex = A.cp_duplex_discounts(parsed[comp])
        for op in parsed[comp].values():
            if op.kind in A._COLLECTIVES:
                g = A._group_size(op.attrs, n_devices)
                wire, _ = A.wire_and_operand_bytes(
                    op.kind, g, op.out_bytes, duplex.get(op.name, 1.0))
                meta = re.search(r'op_name="([^"]+)"', op.attrs)
                rows.append((mult * wire, op.kind, g, mult, op.type_str[:38],
                             (meta.group(1) if meta else "")[-72:]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--zero", type=int, default=3)
    ap.add_argument("--micro-tokens", type=int, default=8192)
    ap.add_argument("--mode", default=None,
                    help="flat|hier|pipelined collective mode")
    ap.add_argument("--backend", default="xla", choices=["xla", "pallas"],
                    help="collective ring backend: xla ppermute rings or "
                         "pallas DMA rings (DESIGN.md §10)")
    ap.add_argument("--stripes", default="auto",
                    help="multi-NIC stripe count of the pallas DMA rings "
                         "(transport layer, DESIGN.md §11).  auto = "
                         "transport.plan_stripes over the mesh's modeled "
                         "cluster; an integer pins it; xla runs resolve to 1")
    ap.add_argument("--policy", default="legacy",
                    choices=["auto", "flat", "legacy"],
                    help="collective policy source (repro.comm, DESIGN.md "
                         "§12): auto = per-op, size-classed PolicyTable "
                         "priced on the mesh's modeled topology (overrides "
                         "--mode/--backend/--stripes); legacy = the "
                         "single-policy facade of those flags; flat = flat "
                         "everywhere")
    ap.add_argument("--n-channels", type=int, default=4,
                    help="pipeline channels of --mode pipelined")
    ap.add_argument("--pipeline-chunk-bytes", type=int, default=None)
    ap.add_argument("--cross-dtype", default=None)
    ap.add_argument("--seq-shard-acts", action="store_true",
                    help="shard the residual stream's seq dim over 'model'")
    ap.add_argument("--moe-no-buf-replication", action="store_true")
    ap.add_argument("--moe-ffn-shard", action="store_true",
                    help="TP inside experts (shard d_ff_expert) instead of "
                         "sharding the expert dim")
    ap.add_argument("--loss-chunk", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--top", type=int, default=8)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a modeled Chrome trace of this cell's "
                         "policy table (repro.obs, DESIGN.md §16)")
    ap.add_argument("--metrics-out", default="results/perf_log.jsonl",
                    metavar="PATH",
                    help="JSONL file the measurement is appended to, in the "
                         "unified obs metric-line schema (kind="
                         "perf_iteration; legacy lines still parse)")
    args = ap.parse_args()

    import dataclasses
    cfg = get_config(args.arch)
    if args.loss_chunk:
        cfg = dataclasses.replace(cfg, loss_chunk=args.loss_chunk)
    if args.attn_chunk:
        cfg = dataclasses.replace(cfg, attn_chunk=args.attn_chunk)
    shape = SHAPES[args.shape]
    multi = args.mesh == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = int(np.prod(mesh.devices.shape))
    model = build(cfg)

    if args.moe_no_buf_replication:
        import repro.models.moe as moe_mod
        import functools
        moe_mod.moe_ffn = functools.partial(moe_mod.moe_ffn, replicate_buffers=False)
        import repro.models.transformer as tfm
        tfm.moe_mod = moe_mod
    if args.seq_shard_acts or args.moe_ffn_shard:
        from repro.models import common as mc
        orig = mc.make_rules

        def patched(cfg_, mesh_, zero_stage=1):
            r = orig(cfg_, mesh_, zero_stage)
            if args.seq_shard_acts:
                r["_attn_sp"] = True
            if args.moe_ffn_shard:
                r["experts"] = None
                r["expert_mlp"] = "model"
            return r
        mc.make_rules = patched
        import repro.train.trainer as tr
        tr.make_rules = patched

    sizes = mesh_axis_sizes(mesh)
    n_pods = sizes.get("pod", 1)
    dp = int(np.prod([sizes.get(a, 1) for a in ("pod", "data")]))
    per_dev = shape.global_batch // dp
    mb = max(1, min(per_dev, args.micro_tokens // shape.seq_len))
    n_micro = per_dev // mb
    plan = uniform_plan(n_pods, n_micro * n_pods, mb)
    from repro.launch.mesh import cluster_for_mesh, resolve_stripes
    n_stripes = resolve_stripes(args.stripes, args.backend, mesh)
    rc = RunConfig(zero_stage=args.zero,
                   collective_mode="flat" if args.policy == "flat"
                   else (args.mode or ("hier" if multi else "flat")),
                   backend=args.backend,
                   n_channels=args.n_channels,
                   n_stripes=n_stripes,
                   pipeline_chunk_bytes=args.pipeline_chunk_bytes,
                   cross_dtype=args.cross_dtype)
    if args.policy == "auto":
        # per-op, size-classed policy table on the mesh's modeled topology
        # (repro.comm, DESIGN.md §12); an explicit --stripes pin narrows
        # the table search like --plan auto narrows its space
        from repro import plan as plan_mod
        space = plan_mod.DEFAULT_SPACE
        if args.stripes != "auto":
            space = dataclasses.replace(space,
                                        stripe_counts=(int(args.stripes),))
        rc = dataclasses.replace(rc, policies=plan_mod.policy_table_for(
            cluster_for_mesh(mesh), space, bucket_bytes=rc.bucket_bytes,
            zero_stage=args.zero))
    batch_sds, extra = _train_batch_sds(cfg, shape, mesh, plan)
    prog = make_train_program(model, mesh, rc, plan, extra_batch_specs=extra)
    state_sds = jax.eval_shape(prog.init_fn, jax.ShapeDtypeStruct((2,), jnp.uint32))
    t0 = time.time()
    compiled = prog.step_fn.lower(state_sds, batch_sds).compile()
    t_compile = time.time() - t0
    hlo = compiled.as_text()
    stats = analyze_hlo(hlo, n_dev, pod_size=pod_size_of(mesh))
    roof = Roofline(arch=args.arch, shape=args.shape, mesh=args.mesh,
                    n_devices=n_dev,
                    model_flops_per_step=model_flops_spec(cfg, shape),
                    stats=stats, xla_flops=0, xla_bytes=0,
                    memory_per_device={
                        "temp_bytes": compiled.memory_analysis().temp_size_in_bytes})
    rec = {"tag": args.tag, "arch": args.arch, "shape": args.shape,
           "mesh": args.mesh, "zero": args.zero, "n_micro": n_micro, "mb": mb,
           "mode": rc.collective_mode, "backend": rc.backend,
           "n_channels": args.n_channels, "n_stripes": rc.n_stripes,
           "policy": args.policy,
           "policies": rc.policies.summary() if rc.policies else None,
           "cross_dtype": args.cross_dtype,
           "seq_shard_acts": args.seq_shard_acts,
           "cross_pod_GB": stats.cross_pod_bytes / 1e9,
           "compute_s": roof.compute_s, "memory_s": roof.memory_s,
           "collective_s": roof.collective_s, "dominant": roof.dominant,
           "step_s": roof.step_s, "roofline_frac": roof.roofline_fraction,
           "useful": roof.useful_flops_fraction,
           "temp_GB": compiled.memory_analysis().temp_size_in_bytes / 1e9,
           "compile_s": round(t_compile, 1)}
    print(json.dumps(rec, indent=1))
    print("top collectives (wire GB/chip x kind x group x loop-mult):")
    for wire, kind, g, mult, tstr, opname in top_collectives(hlo, n_dev, args.top):
        print(f"  {wire / 1e9:9.1f}GB {kind:18s} g={g:<4d} mult={mult:6.0f} "
              f"{tstr:38s} {opname}")
    if args.trace:
        from repro import obs
        from repro import plan as plan_mod
        cl = cluster_for_mesh(mesh)
        table = (rc.policies if rc.policies is not None
                 else plan_mod.policy_table_for(cl))
        obs.write_chrome_trace(args.trace,
                               obs.chrome_trace(obs.modeled_spans(table, cl)))
        print(f"modeled trace: {args.trace}")
    # unified perf JSONL schema (repro.obs, DESIGN.md §16): identity fields
    # are labels, numbers are metrics; read_metric_lines still parses the
    # pre-unification flat records of existing history files
    from repro.obs import append_metric_line, metric_line
    label_keys = ("tag", "arch", "shape", "mesh", "zero", "mode", "backend",
                  "policy", "n_channels", "n_stripes", "cross_dtype",
                  "seq_shard_acts")
    append_metric_line(args.metrics_out, metric_line(
        "perf_iteration",
        labels={k: rec[k] for k in label_keys},
        metrics={k: v for k, v in rec.items() if k not in label_keys}))


if __name__ == "__main__":
    main()
