"""Dump the plan autotuner's candidate frontier (DESIGN.md §9).

    PYTHONPATH=src python -m benchmarks.plan_sweep [--arch smollm-135m]
        [--global-batch 256] [--seq 4096] [--out results/plan_sweep.json]

For each scenario cluster the full ranked frontier is written to JSON (one
row per candidate: mode, channels, bucket, ZeRO stage, shares, modeled
compute/comm/step seconds, HBM feasibility) and the headline rows are
printed in the paper-figs CSV convention (``name,us_per_call,derived`` where
derived = speedup of the chosen plan over the flat baseline), so the
paper-figs pipeline can plot planner frontiers next to the measured-mode
figures.  Pure simulator/numpy — no JAX, runs anywhere in milliseconds.
"""
from __future__ import annotations

import argparse
import json
import os

from repro import plan as plan_mod
from repro.configs import get_config
from repro.core.balance import PodProfile
from repro.core.topology import paper_cluster, tpu_mixed_fleet, tpu_multipod


def scenarios():
    """(name, cluster, data_axis) triples the sweep prices."""
    return (
        ("tpu_multi_4x128", tpu_multipod(4, 128), 8),
        ("tpu_mixed_2v5e_2v4", tpu_mixed_fleet(2, 2, 128), 8),
        ("paper_8nv_8amd", paper_cluster(8, 8), 8),
    )


def sweep(arch: str, global_batch: int, seq_len: int,
          zero: int | None = None, stripes: str = "auto") -> dict:
    """Rank the full space per scenario; returns the JSON-ready record.

    ``stripes``: "auto" searches ``SearchSpace.stripe_counts`` (the transport
    layer's multi-NIC dimension, DESIGN.md §11); an integer pins it.
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    space = plan_mod.DEFAULT_SPACE
    if stripes != "auto":
        space = _dc.replace(space, stripe_counts=(int(stripes),))
    out = {"arch": arch, "global_batch": global_batch, "seq_len": seq_len,
           "scenarios": {}}
    for name, cluster, data_axis in scenarios():
        req = plan_mod.plan_request(cluster, cfg, global_batch, seq_len,
                                    data_axis=data_axis, zero_stage=zero)
        frontier = plan_mod.rank(req, space)
        # measured-drift refinement frontier: slow one island to 60% and
        # re-rank — the what-if the elastic control plane runs (DESIGN.md §9)
        drifted = [PodProfile(p.name, p.effective_flops *
                              (0.6 if i == 0 else 1.0), p.n_chips)
                   for i, p in enumerate(cluster.pods)]
        refined = plan_mod.refined_frontier(frontier[0], drifted)
        out["scenarios"][name] = {
            "frontier": [t.summary() for t in frontier],
            "refined_frontier_drift0.6": [t.summary() for t in refined],
        }
    return out


def csv_rows(record: dict):
    """Headline rows, paper-figs style: chosen plan vs the flat baseline."""
    rows = []
    for name, sc in record["scenarios"].items():
        frontier = sc["frontier"]
        best = frontier[0]
        flat = min((c for c in frontier if c["mode"] == "flat"),
                   key=lambda c: c["modeled_step_s"])
        rows.append((f"plan_sweep/{name}/{record['arch']}/best_"
                     f"{best['mode']}_c{best['n_channels']}"
                     f"_k{best.get('n_stripes', 1)}",
                     best["modeled_step_s"] * 1e6,
                     flat["modeled_step_s"] / best["modeled_step_s"]))
    return rows


def check_striped_frontier(record: dict) -> None:
    """Transport smoke invariant (DESIGN.md §11): wherever stripes were
    searched, the chosen plan's modeled step/comm time is never worse than
    the best stripes=1 candidate — striping is an optimization the planner
    may decline (single-link chips), never a regression it can pick."""
    for name, sc in record["scenarios"].items():
        frontier = sc["frontier"]
        best = frontier[0]
        unstriped = [c for c in frontier if c.get("n_stripes", 1) == 1]
        if not unstriped:
            continue
        floor = min(c["modeled_step_s"] for c in unstriped)
        assert best["modeled_step_s"] <= floor + 1e-12, (name, best, floor)
    return None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--zero", type=int, default=None,
                    help="pin the ZeRO stage (default: search over 1 and 3)")
    ap.add_argument("--stripes", default="auto",
                    help="multi-NIC stripe counts (DESIGN.md §11): auto "
                         "searches SearchSpace.stripe_counts, an integer "
                         "pins one count")
    ap.add_argument("--out", default="results/plan_sweep.json")
    args = ap.parse_args()

    record = sweep(args.arch, args.global_batch, args.seq, args.zero,
                   stripes=args.stripes)
    check_striped_frontier(record)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=1)
    print("name,us_per_call,derived")
    for name, us, derived in csv_rows(record):
        print(f"{name},{us:.3f},{derived:.6g}")
    n = sum(len(s["frontier"]) for s in record["scenarios"].values())
    print(f"# wrote {n} candidates across {len(record['scenarios'])} "
          f"scenarios to {args.out}")


if __name__ == "__main__":
    main()
