"""Quantized-collective smoke (DESIGN.md §17, CI `bench` job):

  1. the planner emits at least one ``wire_quant`` row on the mixed fleet —
     and only on pallas rings in the large class (codecs never reach the
     latency-bound cells);
  2. the quantized table's modeled comm time is <= the same search with the
     codec dimension disabled (quant rows exist only where strictly faster);
  3. watchdog deadline coverage spans every dispatched
     ``(op, size_class, backend, wire_quant)`` cell: a quantized dispatch
     can never hide behind an unquantized deadline.

    PYTHONPATH=src python -m benchmarks.quant_smoke
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    from repro import obs, plan as plan_mod
    from repro.comm import communicator as comm_mod
    from repro.comm.policy import RING_BACKED_OPS
    from repro.configs import get_config
    from repro.core import simulator as sim
    from repro.core.topology import tpu_mixed_fleet
    from repro.elastic.watchdog import derive_deadlines
    from repro.obs.probe import probe_communicator, run_probes
    from repro.plan.autotuner import SearchSpace

    cluster = tpu_mixed_fleet(2, 2, 128)
    req = plan_mod.plan_request(cluster, get_config("smollm-135m"),
                                global_batch=256, seq_len=4096, data_axis=8)

    # -- 1. the planner routes large gradient rings through a codec ---------
    tp = plan_mod.autotune_policies(req)
    assert tp.policies is not None
    quant_rows = {(op, cls): p for (op, cls), p in tp.policies.rows
                  if p.wire_quant}
    assert quant_rows, "mixed-fleet auto table emitted no wire_quant row"
    for (op, cls), p in quant_rows.items():
        assert p.backend == "pallas" and op in RING_BACKED_OPS \
            and cls == "large", (op, cls, p)
    rs_large = tp.policies.lookup("reduce_scatter", "large")
    assert rs_large.wire_quant, \
        f"large gradient reduce_scatter not quantized: {rs_large.label()}"
    assert tp.wire_quant == rs_large.wire_quant

    # -- 2. quantization never models slower than the unquantized search ----
    tp_nq = plan_mod.autotune_policies(req, SearchSpace(wire_quants=(None,)))
    comm_q, comm_nq = tp.modeled_comm_s, tp_nq.modeled_comm_s
    assert comm_q <= comm_nq * (1 + 1e-12), (comm_q, comm_nq)
    # and per quantized row, the codec genuinely beats the same row bare
    for (op, cls), p in quant_rows.items():
        kw = dict(n_channels=p.n_channels, backend=p.backend,
                  n_stripes=p.n_stripes)
        nbytes = float(plan_mod.CLASS_REP_BYTES[cls])
        t_q = sim.collective_time(op, nbytes, req.comm_cluster(), p.mode,
                                  wire_quant=p.wire_quant, **kw)
        t_bare = sim.collective_time(op, nbytes, req.comm_cluster(), p.mode,
                                     **kw)
        assert t_q < t_bare, (op, cls, t_q, t_bare)

    # -- 3. deadline coverage of every dispatched quant cell ----------------
    comm = comm_mod.create(("data",), "pod", table=tp.policies)
    tracer = obs.Tracer(cluster=cluster)
    pc = probe_communicator(comm, tracer)
    n = run_probes(pc)
    assert n > 0, "probe pass dispatched nothing"
    cells = tracer.dispatched_quant_cells()
    assert any(q for *_ignored, q in cells), \
        f"no dispatched cell carries a codec: {sorted(cells)}"
    dt = derive_deadlines(cluster, comm.table)
    missing = dt.missing_cells(cells)
    assert missing == [], f"dispatched cells without deadlines: {missing}"

    n_quant = sum(1 for *_ignored, q in cells if q)
    print(f"quant smoke OK: {len(quant_rows)} planner quant rows "
          f"({', '.join(sorted(op for op, _ in quant_rows))}), modeled comm "
          f"{comm_q*1e3:.3f} ms <= unquantized {comm_nq*1e3:.3f} ms, "
          f"{n} probe dispatches over {len(cells)} cells "
          f"({n_quant} quantized), deadline coverage complete")


if __name__ == "__main__":
    main()
