"""Render the §Roofline table from results/dryrun/*.json.

    PYTHONPATH=src:. python -m benchmarks.roofline_table [--dir results/dryrun]
        [--mesh single|multi|both] [--md results/roofline_table.md]
"""
import argparse
import glob
import json
import os


def load(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(f))
        if r.get("status") == "ok":
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def render(rows, mesh="single"):
    hdr = (f"| arch | shape | mesh | compute_s | memory_s | collective_s | "
           f"xpod_GB | dom | useful | roofline | temp_GB |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        if mesh != "both" and r["mesh"] != mesh:
            continue
        tmp = (r["memory_per_device"]["temp_bytes"] or 0) / 1e9
        xp = r.get("cross_pod_bytes_per_chip", 0) / 1e9
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.2f} | "
            f"{r['collective_s']:.2f} | {xp:.1f} | {r['dominant'][:4]} | "
            f"{r['useful_flops_frac']:.2f} | {r['roofline_frac']:.4f} | "
            f"{tmp:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--md", default="results/roofline_table.md")
    args = ap.parse_args()
    rows = load(args.dir)
    text = render(rows, args.mesh)
    print(text)
    if args.md:
        os.makedirs(os.path.dirname(args.md), exist_ok=True)
        with open(args.md, "w") as f:
            f.write(f"# Roofline table ({args.dir}, {len(rows)} cells)\n\n")
            f.write(text + "\n")


if __name__ == "__main__":
    main()
