"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Simulator-driven figure
reproductions (Figs 7-9, 11, 13-16, Table 4) + measured runs on this host
(real collectives, Fig 12 convergence, Table 4 profiling, kernel refs).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    from benchmarks import paper_figs, real_runs
    print("name,us_per_call,derived")
    failures = 0
    groups = list(paper_figs.ALL) + list(real_runs.ALL)
    if "--sim-only" in sys.argv:
        groups = list(paper_figs.ALL)
    for fn in groups:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.3f},{derived:.6g}", flush=True)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{fn.__name__},ERROR,{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
