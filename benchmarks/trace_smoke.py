"""Trace smoke: run a chaos scenario under the unified telemetry plane and
assert the observability acceptance contract end to end (DESIGN.md §16,
CI `chaos` job):

  - the flight recorder dumps a schema-valid post-mortem on the injected
    fault, and the dump round-trips through ``obs.load_dump``;
  - the injected fault's events (the chaos injection AND the watchdog's
    escalation ladder) are present in the dump, alongside collective spans
    carrying measured time, the simulator's modeled time, and the full
    policy identity (op / size_class / backend / mode / channels / stripes);
  - the Chrome-trace export validates, reloads through the reader, and
    every recorded eager dispatch appears as an "X" event with those tags;
  - ``plan.measured.rows_from_flight`` ingests the dump into calibration
    rows covering every ``(op, size_class, backend)`` cell the run
    dispatched (``Tracer.dispatched_cells()`` — the coverage contract).

    PYTHONPATH=src python -m benchmarks.trace_smoke
"""
import json
import math
import os
import tempfile

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro import elastic, obs
    from repro.configs import get_config
    from repro.configs.base import RunConfig
    from repro.core import compat
    from repro.core.balance import uniform_plan
    from repro.data.pipeline import DataPipeline
    from repro.launch.mesh import cluster_for_mesh
    from repro.models import build
    from repro.plan import measured
    from repro.train.trainer import make_train_program

    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    seq = 64
    mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    prog = make_train_program(
        model, mesh,
        RunConfig(zero_stage=3, collective_mode="hier", learning_rate=1e-3,
                  param_dtype="float32"),
        uniform_plan(2, 2, 1))
    cluster = cluster_for_mesh(mesh)

    def make_batches(p):
        pipe = DataPipeline(seed=0, plan=p.plan, dp_world=p.dp_world(),
                            seq_len=seq, vocab=cfg.vocab)
        return lambda s: {k: jnp.asarray(v)
                          for k, v in pipe.batch_at(s).items()}

    n_steps = 8
    with tempfile.TemporaryDirectory() as d:
        out_dir = os.path.join(d, "tele")
        tel = obs.Telemetry(out_dir=out_dir)
        state = prog.init_fn(jax.random.PRNGKey(1))
        state, report = elastic.run_elastic(
            prog, state, make_batches, cluster=cluster,
            ckpt_dir=os.path.join(d, "e"), n_steps=n_steps,
            script=elastic.parse_script("hang:pod1@4"), telemetry=tel)

        # the run itself behaved as the chaos suite pins it
        assert report.hang_actions == ["retry", "retry", "rebuild"], \
            report.hang_actions
        assert [h["step"] for h in report.history] == list(range(n_steps))

        # -- flight dumps: schema-valid, fault visible ----------------------
        assert tel.dump_paths, "injected fault produced no post-mortem dump"
        reasons = [os.path.basename(p) for p in tel.dump_paths]
        assert any("chaos-hang" in r for r in reasons), reasons
        assert any("hang-rebuild" in r for r in reasons), reasons
        dumps = [obs.load_dump(p) for p in tel.dump_paths]
        for dmp in dumps:
            obs.validate_dump(dmp)

        post = next(dmp for p, dmp in zip(tel.dump_paths, dumps)
                    if "hang-rebuild" in p)
        events = [e for e in post["entries"] if e["kind"] == "event"]
        assert any(e["event"] == "chaos" and e.get("op") == "hang"
                   for e in events), "chaos injection missing from dump"
        hangs = [e for e in events if e["event"] == "hang"]
        assert [e["action"] for e in hangs] == ["retry", "retry", "rebuild"], \
            hangs
        coll = [e for e in post["entries"] if e["kind"] == "span"
                and e.get("cat") == "collective" and e.get("dur_s") is not None]
        assert coll, "no collective spans reached the flight recorder"
        for sp in coll:
            tags = sp["tags"]
            for f in ("op", "size_class", "backend", "mode", "n_channels",
                      "n_stripes", "nbytes", "comm_epoch"):
                assert f in tags, (f, sp)
            assert sp["modeled_s"] is not None and sp["modeled_s"] > 0, sp
            assert sp["residual"] is not None \
                and math.isfinite(sp["residual"]), sp

        # -- final dump: calibration coverage of every dispatched cell ------
        final = tel.flight.dump("final", step=n_steps)
        obs.validate_dump(final)
        rows = measured.rows_from_flight(final, cluster)
        assert rows, "flight ingest produced no calibration rows"
        for r in rows:
            assert r.group == "flight" and r.measured_s > 0 \
                and r.modeled_s > 0, r
        covered = set(measured.flight_cells(rows))
        dispatched = tel.tracer.dispatched_cells()
        assert dispatched, "run recorded no eager dispatches"
        assert covered == dispatched, (
            "calibration coverage != dispatched cells",
            sorted(dispatched - covered), sorted(covered - dispatched))

        # -- chrome trace: writes, validates, reloads, spans tagged ---------
        paths = tel.write(metrics_out=os.path.join(d, "metrics.jsonl"))
        trace = obs.load_chrome_trace(paths["trace"])
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"
              and e.get("cat") == "collective"]
        assert len(xs) >= len(dispatched), (len(xs), len(dispatched))
        for ev in xs:
            for f in ("op", "size_class", "backend", "modeled_s", "residual"):
                assert f in ev["args"], (f, ev)
        obs.validate_chrome_trace(obs.chrome_trace(dump=final))
        lines = obs.read_metric_lines(paths["metrics_out"])
        assert [ln["kind"] for ln in lines] == ["fleet_snapshot"], lines

        # -- fleet metrics saw the whole story ------------------------------
        snap = tel.snapshot()
        assert snap["schema_version"] == obs.METRICS_SCHEMA_VERSION
        counters = {(c["name"], tuple(sorted(c["labels"].items()))):
                    c["value"] for c in snap["counters"]}
        total_disp = sum(v for (n, _), v in counters.items()
                         if n == "collective_dispatch_total")
        assert total_disp == len([s for s in tel.tracer.collective_spans()]), \
            total_disp
        assert sum(v for (n, _), v in counters.items()
                   if n == "watchdog_breach_total") == 3
        assert sum(v for (n, _), v in counters.items()
                   if n == "chaos_actions_total") >= 1
        assert json.loads(json.dumps(snap)) == snap   # JSON-clean

        report_txt = tel.step_report()
        assert "collective time share" in report_txt
        assert "top residuals" in report_txt

        print(f"trace smoke: {total_disp} dispatches over "
              f"{len(dispatched)} (op,class,backend) cells, "
              f"{len(rows)} calibration rows, {len(tel.dump_paths)} dumps "
              f"({', '.join(sorted(set(r.split('-', 2)[-1].rsplit('.', 1)[0] for r in reasons)))}), "
              f"chrome trace {len(trace['traceEvents'])} events")
        print("trace smoke OK")


if __name__ == "__main__":
    main()
