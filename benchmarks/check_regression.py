"""Variance-aware bench regression gate (DESIGN.md §14).

Compares a current ``BENCH_*.json`` run against the committed baseline and
fails **only** when a case's median regresses by more than ``--threshold``
*and* the two runs' IQRs don't overlap — a slow case must be both large and
statistically separated from the baseline's noise band to trip the gate, so
ordinary CI jitter (which widens the IQRs) loosens the gate automatically
instead of flaking it.

Within-run IQRs underestimate *between-process* variance, and how badly
depends on duration: on CPU meshes, sub-millisecond collectives drift tens
of percent between runs (dispatch/cache state), while 100ms+ cases are
stable within ~15%.  Each side's IQR band is therefore inflated to at least
a duration-scaled noise floor (±35% under 2ms, ±25% under 20ms, ±10%
above) before testing overlap — so the effective bar for a tiny case is
"well beyond plausible run-to-run noise", while long-running cases are
gated tightly.

Because the committed baseline was measured on some other machine, raw
medians are incomparable across hosts.  The gate therefore normalizes by a
*host factor*: the geometric median of current/baseline median ratios across
all shared cases.  A uniformly slower host moves every ratio together — the
factor absorbs it.  A genuine regression moves only its own cases, sticks
out above the (robust) factor, and still fails.  ``--no-normalize`` compares
raw seconds (same-host A/B runs).  Corollary: normalization needs breadth —
with a single shared case (``BENCH_train.json``) the factor *is* that case's
ratio and the normalized gate reduces to a schema/join check; cross-run
train-step drift is caught by the 81-case comm record, not the 1-case train
record.

    PYTHONPATH=src python -m benchmarks.check_regression \
        BENCH_comm.json /tmp/bench/BENCH_comm.json [--threshold 0.25]

Exit codes: 0 pass (including missing-baseline, which warns — a brand-new
bench trajectory must not fail its own bootstrap PR), 1 regression, 2 bad
input (unreadable/invalid record).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import pathlib
import sys
from typing import Mapping, Sequence

DEFAULT_THRESHOLD = 0.25    # fail at >25% normalized median regression

# Duration-scaled between-run noise floors: each run's IQR band is widened
# to at least ±floor around its median before the overlap test.  Calibrated
# against observed same-host run-to-run drift of the CPU-mesh harness
# (sub-2ms cases drift up to ~1.8x between processes; >100ms cases <1.15x).
NOISE_FLOOR_STEPS = ((2e-3, 0.35), (20e-3, 0.25), (float("inf"), 0.10))


def noise_floor(median_s: float) -> float:
    """Minimum relative half-width of a case's noise band, by duration."""
    for limit, floor in NOISE_FLOOR_STEPS:
        if median_s < limit:
            return floor
    return NOISE_FLOOR_STEPS[-1][1]


@dataclasses.dataclass(frozen=True)
class CaseResult:
    """Verdict for one shared case name."""

    name: str
    baseline_median_s: float
    current_median_s: float
    ratio: float                # current / (baseline * host_factor)
    regressed: bool             # ratio > 1 + threshold
    iqr_overlap: bool           # scaled baseline IQR ∩ current IQR
    fail: bool                  # regressed AND not iqr_overlap

    def line(self) -> str:
        verdict = "FAIL" if self.fail else \
            ("slow (IQR overlap)" if self.regressed else "ok")
        return (f"{self.name}: x{self.ratio:.3f} "
                f"({self.baseline_median_s * 1e6:.0f}us -> "
                f"{self.current_median_s * 1e6:.0f}us) {verdict}")


def _entry_map(record: Mapping) -> dict[str, Mapping]:
    return {e["name"]: e for e in record["entries"]}


def host_factor(baseline: Mapping, current: Mapping) -> float:
    """Geometric median of per-case current/baseline median ratios — the
    robust 'how much slower is this host overall' estimate.  A minority of
    genuinely-regressed cases can't drag it (median), so they still stand
    out after normalization."""
    base, cur = _entry_map(baseline), _entry_map(current)
    logs = sorted(
        math.log10(cur[n]["median_s"] / base[n]["median_s"])
        for n in base.keys() & cur.keys()
        if base[n]["median_s"] > 0 and cur[n]["median_s"] > 0)
    if not logs:
        return 1.0
    mid = len(logs) // 2
    med = logs[mid] if len(logs) % 2 else (logs[mid - 1] + logs[mid]) / 2
    return 10.0 ** med


def compare(baseline: Mapping, current: Mapping,
            threshold: float = DEFAULT_THRESHOLD,
            normalize: bool = True) -> list[CaseResult]:
    """Per-case verdicts over the names both records share.  New cases
    (no baseline) and removed cases (no current) never fail — the gate
    guards timings, renames are the review's job."""
    base, cur = _entry_map(baseline), _entry_map(current)
    factor = host_factor(baseline, current) if normalize else 1.0
    results = []
    for name in sorted(base.keys() & cur.keys()):
        b, c = base[name], cur[name]
        scaled_median = b["median_s"] * factor
        ratio = c["median_s"] / scaled_median if scaled_median > 0 \
            else float("inf")
        regressed = ratio > 1.0 + threshold
        # IQR overlap in the normalized (current-host) time scale, each
        # band widened to at least the duration-scaled noise floor.
        bf, cf = noise_floor(b["median_s"]), noise_floor(c["median_s"])
        b_lo = min(b["iqr_lo_s"], b["median_s"] * (1 - bf)) * factor
        b_hi = max(b["iqr_hi_s"], b["median_s"] * (1 + bf)) * factor
        c_lo = min(c["iqr_lo_s"], c["median_s"] * (1 - cf))
        c_hi = max(c["iqr_hi_s"], c["median_s"] * (1 + cf))
        overlap = b_lo <= c_hi and c_lo <= b_hi
        results.append(CaseResult(
            name=name, baseline_median_s=b["median_s"],
            current_median_s=c["median_s"], ratio=ratio,
            regressed=regressed, iqr_overlap=overlap,
            fail=regressed and not overlap))
    return results


def _load(path: pathlib.Path) -> dict:
    from benchmarks.measure import validate
    return validate(json.loads(path.read_text()))


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=pathlib.Path,
                    help="committed BENCH_*.json snapshot")
    ap.add_argument("current", type=pathlib.Path,
                    help="freshly measured BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="median regression fraction that (with disjoint "
                         f"IQRs) fails the gate (default "
                         f"{DEFAULT_THRESHOLD})")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare raw seconds (same-host A/B) instead of "
                         "host-factor-normalized ratios")
    args = ap.parse_args(argv)

    if not args.baseline.exists():
        print(f"check_regression: no baseline at {args.baseline} — "
              "nothing to gate against (pass)", file=sys.stderr)
        return 0
    try:
        baseline = _load(args.baseline)
        current = _load(args.current)
    except (OSError, ValueError, KeyError) as e:
        print(f"check_regression: bad input: {e}", file=sys.stderr)
        return 2

    normalize = not args.no_normalize
    results = compare(baseline, current, args.threshold, normalize)
    if not results:
        print("check_regression: no shared case names — nothing to compare "
              "(pass)", file=sys.stderr)
        return 0
    factor = host_factor(baseline, current) if normalize else 1.0
    print(f"check_regression: {len(results)} shared cases, host factor "
          f"x{factor:.3f}, threshold {args.threshold:.0%}")
    failed = [r for r in results if r.fail]
    for r in results:
        if r.fail or r.regressed:
            print("  " + r.line())
    if failed:
        print(f"check_regression: {len(failed)} regression(s) over "
              f"{args.threshold:.0%} with disjoint IQRs", file=sys.stderr)
        return 1
    print("check_regression: pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
